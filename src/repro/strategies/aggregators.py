"""Registered aggregation strategies.

Every aggregator maps a :class:`~repro.strategies.base.RoundContext` to a
``[N]`` simplex weight vector consumed by the fused weighted-sum
aggregation (Algorithm 1 line 14 / the ``weighted_aggregate`` Pallas
kernel). The paper's three schemes plus three standard robust baselines:

* ``fedtest``        — moving-average accuracy^p scores from peer testers
  (the paper's contribution, Sec. III).
* ``fedavg``         — weights proportional to client sample counts
  [McMahan et al.].
* ``accuracy_based`` — weights from accuracy on the *server's* held-out
  set (TiFL-style; the scheme FedTest improves upon, Fig. 3a).
* ``krum`` — [Blanchard et al., NeurIPS'17] pick the client(s) whose
  update is closest to its n-f-2 nearest neighbours (``multi=`` gives
  Multi-Krum).
* ``trimmed_mean``   — [Yin et al., ICML'18] client-level variant: drop
  the beta-fraction of clients farthest from the coordinate-wise median
  update, average the rest uniformly.
* ``median``         — geometric-median weights via Weiszfeld iteration
  (a smooth stand-in for coordinate-wise median that stays a weighted
  sum, so the one fused aggregation kernel is preserved).

Plus the true *per-coordinate* defences of the poisoning literature,
which cannot be expressed as a client weight simplex at all — they ride
the ``Aggregator.combine()`` fast path (the ``robust_combine``
sorting-network kernel) instead of the weighted sum:

* ``trimmed_mean_coord`` — [Yin et al., ICML'18] coordinate-wise
  beta-trimmed mean of the client updates.
* ``median_coord``       — coordinate-wise median of the client updates.

Both take an optional ``score_gate``: clients whose FedTest
moving-average score falls below ``score_gate * max(scores)`` are masked
out of the order statistic, composing the paper's cross-testing signal
with the update-space defence.

The robust baselines operate on ``ctx.updates`` — the ``[N, D]`` float32
matrix of flattened client updates — which the engine materialises only
when ``needs_updates`` is set (or ``combine`` is defined). Under client
sampling every one of them confines its statistic to the sampled subset
(``ctx.participation``): a non-participant's slot holds the reverted
stale-global update — an all-zero row whose mutual distance of 0 would
otherwise *win* Krum and drag the median toward the origin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scoring import (
    score_weights, update_scores, update_tester_trust)
from repro.kernels.robust_combine import robust_combine
from repro.strategies.base import (
    AGGREGATORS, Aggregator, RoundContext, register)


def _uniform(n: int) -> jnp.ndarray:
    return jnp.full((n,), 1.0 / n, jnp.float32)


def _mask_to_simplex(mask: jnp.ndarray) -> jnp.ndarray:
    m = mask.astype(jnp.float32)
    return m / jnp.maximum(m.sum(), 1e-9)


@register(AGGREGATORS, "fedtest")
class FedTest(Aggregator):
    """The paper's scheme: normalised moving-average accuracy^p scores.

    ``use_trust`` enables the Sec. V-C tester-trust consensus: testers
    whose reports deviate from the per-round median lose trust and their
    future reports are down-weighted. ``trust_decay`` is that moving
    average's memory — the default 0.8 suits occasional noisy reporters;
    coordinated lying testers (the ``mutual_boost`` coalition,
    DESIGN.md §7) need a faster forgetting rate (the coalition presets
    use 0.3) so a member's trust collapses within a couple of observed
    lying rounds instead of leaking boosts for ten. ``report_clip`` adds
    the bounded-influence winsorisation of reports against the per-client
    consensus median — the trust signal needs a round of evidence before
    it bites, and the clip is what caps a coalition's round-1 boost (when
    every model is at chance, an unclipped 1.0-report more than doubles a
    member's relative score; DESIGN.md §7).
    """

    def __init__(self, *, score_power: float = 4.0, score_decay: float = 0.5,
                 power_warmup_rounds: int = 2, use_trust: bool = False,
                 trust_decay: float = 0.8, report_clip: float = 0.0):
        if not 0.0 <= trust_decay <= 1.0:
            raise ValueError(f"trust_decay in [0, 1], got {trust_decay}")
        if not 0.0 <= report_clip <= 1.0:
            raise ValueError(f"report_clip in [0, 1], got {report_clip}")
        self.score_power = float(score_power)
        self.score_decay = float(score_decay)
        self.power_warmup_rounds = int(power_warmup_rounds)
        self.use_trust = bool(use_trust)
        self.trust_decay = float(trust_decay)
        self.report_clip = float(report_clip)

    def update_scores(self, ctx: RoundContext):
        scores = ctx.scores
        if self.use_trust:
            scores = update_tester_trust(scores, ctx.acc_matrix,
                                         ctx.tester_ids,
                                         decay=self.trust_decay,
                                         row_mask=ctx.report_mask)
        return update_scores(scores, ctx.acc_matrix, ctx.tester_ids,
                             power=self.score_power,
                             decay=self.score_decay,
                             use_trust=self.use_trust,
                             power_warmup_rounds=self.power_warmup_rounds,
                             row_mask=ctx.report_mask,
                             client_mask=ctx.participation,
                             report_clip=self.report_clip or None)

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        return score_weights(ctx.scores)


@register(AGGREGATORS, "fedavg")
class FedAvg(Aggregator):
    """Weights proportional to client sample counts [McMahan et al.]."""

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        c = ctx.counts.astype(jnp.float32)
        return c / jnp.maximum(c.sum(), 1e-9)


@register(AGGREGATORS, "accuracy_based")
class AccuracyBased(Aggregator):
    """Server-side accuracy weighting (the baseline of Fig. 3a)."""

    needs_server_eval = True

    def __init__(self, *, power: float = 1.0):
        self.power = float(power)

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        acc = ctx.server_eval()
        a = jnp.clip(acc.astype(jnp.float32), 0.0, 1.0) ** self.power
        total = jnp.sum(a)
        n = a.shape[0]
        return jnp.where(total > 1e-12, a / jnp.maximum(total, 1e-12),
                         jnp.full_like(a, 1.0 / n))


def _pairwise_sq_dists(u: jnp.ndarray) -> jnp.ndarray:
    """[N, D] -> [N, N] squared euclidean distances."""
    sq = jnp.sum(u * u, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (u @ u.T)
    return jnp.maximum(d2, 0.0)


# non-participant exclusion distance: finite (inf would poison the
# neighbour sums when k exceeds the sampled-subset size) but far above
# any real update distance, so excluded pairs are always ranked last
_FAR = 1e12


def _krum_scores(u: jnp.ndarray, num_byzantine: int,
                 part=None) -> jnp.ndarray:
    """Krum score per client: sum of sq-dists to its n-f-2 nearest peers.

    ``part`` [N] excludes non-participants (client sampling): their slot
    holds a reverted stale-global update (a zero row — mutual distance 0,
    which would otherwise *win* Krum), so pairs touching a non-participant
    are pushed beyond any honest distance and non-participants' own
    scores are +inf, keeping the selection inside the sampled subset.
    """
    n = u.shape[0]
    d2 = _pairwise_sq_dists(u)
    d2 = jnp.where(jnp.eye(n, dtype=bool), _FAR, d2)      # exclude self
    if part is not None:
        excl = (part[:, None] <= 0) | (part[None, :] <= 0)
        d2 = jnp.where(excl, _FAR, d2)
    k = max(1, min(n - 1, n - num_byzantine - 2))
    nearest = -jax.lax.top_k(-d2, k)[0]     # [N, k] smallest per row
    scores = jnp.sum(nearest, axis=1)
    if part is not None:
        scores = jnp.where(part > 0, scores, jnp.inf)
    return scores


@register(AGGREGATORS, "krum")
class Krum(Aggregator):
    """Krum / Multi-Krum [Blanchard et al., NeurIPS'17].

    Selects the ``multi`` clients with the smallest Krum score and weighs
    them uniformly (``multi=1`` is classic Krum: a one-hot simplex).
    ``num_byzantine`` is the defender's assumed upper bound f; the engine
    defaults it to ``FedConfig.num_malicious``.
    """

    needs_updates = True

    def __init__(self, *, num_byzantine: int = 0, multi: int = 1):
        self.num_byzantine = int(num_byzantine)
        self.multi = max(1, int(multi))

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        scores = _krum_scores(ctx.updates, self.num_byzantine,
                              part=ctx.participation)
        n = scores.shape[0]
        m = min(self.multi, n)
        _, best = jax.lax.top_k(-scores, m)
        mask = jnp.zeros((n,), jnp.float32).at[best].set(1.0)
        if ctx.participation is not None:
            mask = mask * ctx.participation
        return _mask_to_simplex(mask)


@register(AGGREGATORS, "trimmed_mean")
class TrimmedMean(Aggregator):
    """Client-level trimmed mean [after Yin et al., ICML'18].

    Ranks clients by distance of their update to the coordinate-wise
    median update and drops the ``trim_fraction`` farthest; the survivors
    are averaged uniformly. Expressed as a simplex so the fused weighted
    aggregation is unchanged.
    """

    needs_updates = True

    def __init__(self, *, trim_fraction: float = 0.2):
        if not 0.0 <= trim_fraction < 1.0:
            raise ValueError(f"trim_fraction in [0, 1), got {trim_fraction}")
        self.trim_fraction = float(trim_fraction)

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        u = ctx.updates
        n = u.shape[0]
        part = ctx.participation
        if part is None:
            med = jnp.median(u, axis=0)
        else:
            # consensus over the sampled subset only: non-participants'
            # slots are reverted zero rows that would drag the median
            med = jnp.nanmedian(
                jnp.where(part[:, None] > 0, u, jnp.nan), axis=0)
        dist = jnp.linalg.norm(u - med[None, :], axis=1)
        if part is not None:
            dist = jnp.where(part > 0, dist, jnp.inf)
        keep = max(1, n - int(round(self.trim_fraction * n)))
        _, kept = jax.lax.top_k(-dist, keep)
        mask = jnp.zeros((n,), jnp.float32).at[kept].set(1.0)
        if part is not None:
            mask = mask * part
        return _mask_to_simplex(mask)


@register(AGGREGATORS, "median")
class GeometricMedian(Aggregator):
    """Geometric-median weights via Weiszfeld iteration.

    Fixed-point weights ``w_i ∝ 1 / ||u_i - mu||`` where ``mu`` is the
    current weighted mean; a few iterations converge to the geometric
    median of the client updates, which a single adversarial update cannot
    drag arbitrarily far (breakdown point 1/2).
    """

    needs_updates = True

    def __init__(self, *, iters: int = 4, eps: float = 1e-6):
        self.iters = int(iters)
        self.eps = float(eps)

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        u = ctx.updates
        n = u.shape[0]
        # the fixed point runs over the sampled subset: non-participants'
        # reverted zero rows would pull the median toward the origin
        gate = (jnp.ones((n,), jnp.float32) if ctx.participation is None
                else ctx.participation)
        w = gate / jnp.maximum(gate.sum(), 1e-9)
        for _ in range(self.iters):
            mu = w @ u
            dist = jnp.linalg.norm(u - mu[None, :], axis=1)
            w = gate / (dist + self.eps)
            w = w / jnp.maximum(w.sum(), 1e-12)
        return w


class _CoordRobust(Aggregator):
    """Shared machinery of the per-coordinate combine aggregators.

    The client *gate mask* decides who enters the per-coordinate order
    statistic: everyone by default, optionally filtered by the FedTest
    moving-average scores (``score_gate``) and always intersected with
    the round's participation mask. ``weights()`` returns the normalised
    gate — used only for reporting (``malicious_weight``), never for the
    reduction itself.

    These aggregators maintain the FedTest moving-average scores
    themselves (same ``update_scores`` as the ``fedtest`` scheme) so the
    gate has a live cross-testing signal to act on — without it the
    scores would sit at their all-zero init and the gate would never
    engage.
    """

    needs_updates = True

    def __init__(self, *, trim_fraction: float = 0.2,
                 score_gate: float = 0.0, impl: str = "auto",
                 score_power: float = 4.0, score_decay: float = 0.5,
                 power_warmup_rounds: int = 2):
        if not 0.0 <= trim_fraction < 1.0:
            raise ValueError(f"trim_fraction in [0, 1), got {trim_fraction}")
        if not 0.0 <= score_gate <= 1.0:
            raise ValueError(f"score_gate in [0, 1], got {score_gate}")
        self.trim_fraction = float(trim_fraction)
        self.score_gate = float(score_gate)
        self.impl = impl
        self.score_power = float(score_power)
        self.score_decay = float(score_decay)
        self.power_warmup_rounds = int(power_warmup_rounds)

    _mode = "trimmed_mean"

    def update_scores(self, ctx: RoundContext):
        return update_scores(ctx.scores, ctx.acc_matrix, ctx.tester_ids,
                             power=self.score_power,
                             decay=self.score_decay,
                             power_warmup_rounds=self.power_warmup_rounds,
                             row_mask=ctx.report_mask,
                             client_mask=ctx.participation)

    def gate_mask(self, ctx: RoundContext) -> jnp.ndarray:
        mask = jnp.ones((ctx.num_users,), jnp.float32)
        if self.score_gate > 0.0:
            s = jnp.maximum(ctx.scores.scores, 0.0)
            gated = (s >= self.score_gate * jnp.max(s)).astype(jnp.float32)
            # before any scores exist (round 0) the gate would be
            # degenerate — keep everyone until the signal is non-zero
            mask = jnp.where(jnp.max(s) > 0.0, gated, mask)
        if ctx.participation is not None:
            mask = mask * ctx.participation
        # the statistic needs at least one client; an empty gate falls
        # back to the full participation set
        return jnp.where(mask.sum() > 0.0, mask,
                         ctx.participation if ctx.participation is not None
                         else jnp.ones_like(mask))

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        return _mask_to_simplex(self.gate_mask(ctx))

    def combine(self, ctx: RoundContext, updates: jnp.ndarray) -> jnp.ndarray:
        return robust_combine(updates, mask=self.gate_mask(ctx),
                              mode=self._mode,
                              trim_fraction=self.trim_fraction,
                              impl=self.impl)


@register(AGGREGATORS, "trimmed_mean_coord")
class CoordTrimmedMean(_CoordRobust):
    """Coordinate-wise beta-trimmed mean [Yin et al., ICML'18]."""

    _mode = "trimmed_mean"


@register(AGGREGATORS, "median_coord")
class CoordMedian(_CoordRobust):
    """Coordinate-wise median [Yin et al., ICML'18]."""

    _mode = "median"


@register(AGGREGATORS, "uniform")
class Uniform(Aggregator):
    """Plain mean — the no-defence control."""

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        return _uniform(ctx.num_users)
