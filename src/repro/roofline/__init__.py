from repro.roofline.hw import TPU_V5E
from repro.roofline.analysis import (
    parse_collectives, roofline_terms, model_flops)

__all__ = ["TPU_V5E", "parse_collectives", "roofline_terms", "model_flops"]
