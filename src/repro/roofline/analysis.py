"""Roofline-term derivation from compiled dry-run artifacts.

* HLO_FLOPs / HLO_bytes — from ``compiled.cost_analysis()``.
* collective bytes       — parsed from the post-SPMD ``compiled.as_text()``:
  shapes there are *per-partition*, so summed operand/output sizes are
  bytes-per-device directly. All-reduce counts 2x (reduce-scatter +
  all-gather decomposition on a ring); the others 1x.

    compute_term    = HLO_FLOPs / (chips * peak)        [s]
    memory_term     = HLO_bytes / (chips * hbm_bw)      [s]
    collective_term = coll_bytes_per_dev / link_bw      [s]

cost_analysis flops/bytes are *whole-program* totals for the partitioned
module as compiled for one logical program: with SPMD partitioning the
reported numbers are per-partition, so we do NOT divide by chips again —
``per_device=True`` flags that. (The CPU-backend dry-run compiles the
partitioned module, so numbers arrive per-device.)
"""
from __future__ import annotations

import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"=\s*(.+?)\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum per-device output bytes of every collective op, by op kind."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:          # async pair: count the -start only
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        nbytes = _shape_bytes(m.group(1))
        op = m.group(2)
        out[op] = out.get(op, 0) + nbytes
    return out


def collective_bytes_per_device(colls: Dict[str, int]) -> float:
    factors = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(b * factors.get(op, 1.0) for op, b in colls.items())


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, chip, num_chips: int,
                   per_device: bool = True) -> Dict[str, float]:
    div = 1 if per_device else num_chips
    compute = flops / div / chip.peak_flops_bf16
    memory = bytes_accessed / div / chip.hbm_bw
    collective = coll_bytes / chip.ici_link_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def model_flops(cfg, shape, active: bool = True) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference steps
    (N = (active) params, D = tokens processed)."""
    n = cfg.active_param_count() if active else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
