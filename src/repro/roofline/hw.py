"""Target-hardware constants (TPU v5e, per chip)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bw: float               # bytes/s
    ici_link_bw: float          # bytes/s per link
    hbm_bytes: float
    vmem_bytes: float


TPU_V5E = Chip(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16 * 1024 ** 3,
    vmem_bytes=128 * 1024 ** 2,
)
