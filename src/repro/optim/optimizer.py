"""Pytree optimizers: SGD / momentum / Adam / AdamW, with grad clipping.

No optax dependency — states are plain pytrees so the FL engine can stack
them along a client axis and the launchers can shard them like params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.schedules import make_schedule
from repro.utils import tree_l2_norm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]
    name: str = ""


def _clip(grads, max_norm):
    if not max_norm or max_norm <= 0:
        return grads
    norm = tree_l2_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_optimizer(cfg) -> Optimizer:
    """cfg: TrainConfig."""
    sched = make_schedule(cfg)

    if cfg.optimizer == "sgd":
        def init(params):
            return {"step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            grads = _clip(grads, cfg.grad_clip)
            lr = sched(state["step"])
            new = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, {"step": state["step"] + 1}
        return Optimizer(init, update, "sgd")

    if cfg.optimizer == "momentum":
        def init(params):
            return {"step": jnp.zeros((), jnp.int32),
                    "mu": jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}

        def update(grads, state, params):
            grads = _clip(grads, cfg.grad_clip)
            lr = sched(state["step"])
            mu = jax.tree_util.tree_map(
                lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            new = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, mu)
            return new, {"step": state["step"] + 1, "mu": mu}
        return Optimizer(init, update, "momentum")

    if cfg.optimizer in ("adam", "adamw"):
        wd = cfg.weight_decay if cfg.optimizer == "adamw" else 0.0

        def init(params):
            z = lambda p: jnp.zeros(p.shape, jnp.float32)
            return {"step": jnp.zeros((), jnp.int32),
                    "m": jax.tree_util.tree_map(z, params),
                    "v": jax.tree_util.tree_map(z, params)}

        def update(grads, state, params):
            grads = _clip(grads, cfg.grad_clip)
            step = state["step"] + 1
            lr = sched(state["step"])
            b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
            m = jax.tree_util.tree_map(
                lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                state["m"], grads)
            v = jax.tree_util.tree_map(
                lambda v_, g: b2 * v_ + (1 - b2)
                * jnp.square(g.astype(jnp.float32)),
                state["v"], grads)
            bc1 = 1 - b1 ** step.astype(jnp.float32)
            bc2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, m_, v_):
                u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                if wd:
                    u = u + wd * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

            new = jax.tree_util.tree_map(upd, params, m, v)
            return new, {"step": step, "m": m, "v": v}
        return Optimizer(init, update, cfg.optimizer)

    raise ValueError(cfg.optimizer)
