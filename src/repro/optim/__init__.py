from repro.optim.optimizer import Optimizer, make_optimizer
from repro.optim.schedules import make_schedule

__all__ = ["Optimizer", "make_optimizer", "make_schedule"]
