"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(cfg):
    """cfg: TrainConfig -> step -> lr."""
    base = cfg.lr
    warmup = max(cfg.warmup_steps, 0)
    total = max(cfg.total_steps, 1)

    if cfg.schedule == "constant":
        def sched(step):
            return jnp.asarray(base, jnp.float32)
    elif cfg.schedule == "cosine":
        def sched(step):
            frac = jnp.clip(step / total, 0.0, 1.0)
            return base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear_warmup_cosine":
        def sched(step):
            wu = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
            frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                            0.0, 1.0)
            return base * wu * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    else:
        raise ValueError(cfg.schedule)
    return sched
