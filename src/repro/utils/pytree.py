"""Pytree arithmetic helpers used by optimizers and the FL aggregators."""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def tree_size(tree: Pytree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_weighted_sum(trees: Sequence[Pytree], weights) -> Pytree:
    """sum_i w_i * tree_i  — the FL aggregation primitive.

    ``trees`` may be a list of pytrees, or a single *stacked* pytree whose
    leaves carry a leading client axis; ``weights`` is a vector of matching
    length. The stacked form is the one used on device.
    """
    weights = jnp.asarray(weights)
    if isinstance(trees, (list, tuple)):
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)
    else:
        stacked = trees

    def _comb(x):
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0).astype(x.dtype)

    return jax.tree_util.tree_map(_comb, stacked)


def tree_add_vector(tree: Pytree, vec: jnp.ndarray) -> Pytree:
    """``tree + unflatten(vec)``: scatter a flat [D] update onto leaves.

    ``vec`` follows ``tree_leaves`` order with each leaf flattened — the
    layout produced by the round engine's ``[N, D]`` update matrix — so
    this is the inverse of that flattening, fused with the add. Offsets
    are static, so the split is free under jit.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for leaf in leaves:
        part = vec[off:off + leaf.size].reshape(leaf.shape)
        out.append((leaf.astype(jnp.float32) + part).astype(leaf.dtype))
        off += leaf.size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_l2_norm(tree: Pytree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
