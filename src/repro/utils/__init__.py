from repro.utils.pytree import (
    tree_bytes,
    tree_size,
    tree_zeros_like,
    tree_weighted_sum,
    tree_add,
    tree_add_vector,
    tree_scale,
    tree_l2_norm,
    tree_cast,
)
from repro.utils.prng import key_iter, fold_in_name

__all__ = [
    "tree_bytes",
    "tree_size",
    "tree_zeros_like",
    "tree_weighted_sum",
    "tree_add",
    "tree_add_vector",
    "tree_scale",
    "tree_l2_norm",
    "tree_cast",
    "key_iter",
    "fold_in_name",
]
