"""Population-scale data providers for the cohort engine (DESIGN.md §11).

The dense :class:`~repro.data.pipeline.FederatedDataset` materialises
every client's shard as rows of one [N, M, ...] stack — at N = 10⁵ the
stack alone is tens of GB, and the population tier never reads more
than the sampled cohort's rows anyway. A *population provider* exposes
exactly the gather surface :class:`~repro.core.engine.population.
PopulationTrainer` needs:

* ``train_counts``            — [N] per-client sample counts (cheap)
* ``cohort_train(idx)``       — the cohort's [C, M, ...] train shards
* ``tester_batches(ids, b)``  — the K testers' [K, b, ...] eval rows
* ``server_batch(b)``         — the server's (sx, sy) eval slice
* ``global_x`` / ``global_y`` — the convergence-curve eval set

Two implementations:

:class:`DensePopulationData` wraps an existing materialised dataset —
the parity bridge: its gathers return bitwise the rows the dense driver
reads, so small-N population runs pin against ``FederatedTrainer``
exactly (``tests/test_population.py``).

:class:`SyntheticPopulation` materialises nothing per-client: shards
are derived on demand from ``fold_in(key, client)`` streams over shared
class prototypes, so a 10⁵-client population costs O(prototypes), and
only the sampled cohort's images ever exist on device — the provider
behind ``benchmarks/bench_population.py``'s N-sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import FederatedDataset

# disjoint fold_in constants deriving the per-client data streams from
# the provider's base key (FL001: derive, never reuse)
TRAIN_STREAM = 0
TEST_STREAM = 1
GLOBAL_STREAM = 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DensePopulationData:
    """Population view over a materialised :class:`FederatedDataset`.

    Gathers return the same rows (bitwise) the dense driver reads from
    the stacked arrays — the small-N parity bridge.
    """

    dense: FederatedDataset

    @property
    def num_clients(self) -> int:
        return self.dense.train.num_clients

    @property
    def train_counts(self) -> jnp.ndarray:
        return self.dense.train.counts

    @property
    def global_x(self) -> jnp.ndarray:
        return self.dense.global_x

    @property
    def global_y(self) -> jnp.ndarray:
        return self.dense.global_y

    def cohort_train(self, idx) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.dense.train.xs[idx], self.dense.train.ys[idx]

    def tester_batches(self, tester_ids, eval_batch: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        # gather-then-slice == the dense driver's slice-then-gather
        return (self.dense.test.xs[tester_ids][:, :eval_batch],
                self.dense.test.ys[tester_ids][:, :eval_batch])

    def server_batch(self, eval_batch: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (self.dense.server_x[:eval_batch],
                self.dense.server_y[:eval_batch])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SyntheticPopulation:
    """Derive-on-gather population: shards exist only while sampled.

    Client ``i``'s train shard is a pure function of
    ``fold_in(fold_in(key, TRAIN_STREAM), i)`` over the shared class
    prototypes (class-conditional images + Gaussian noise, the
    ``repro.data.synthetic`` recipe), its tester shard of the disjoint
    ``TEST_STREAM`` — so gathers are deterministic, resume-stable, and
    O(cohort) in memory regardless of the population size.
    """

    key: jnp.ndarray                 # base data key
    protos: jnp.ndarray              # [num_classes, H, W, C] prototypes
    global_x: jnp.ndarray
    global_y: jnp.ndarray
    server_x: jnp.ndarray
    server_y: jnp.ndarray
    num_clients: int = dataclasses.field(metadata=dict(static=True))
    per_client: int = dataclasses.field(metadata=dict(static=True))
    noise: float = dataclasses.field(metadata=dict(static=True))

    @property
    def num_classes(self) -> int:
        return self.protos.shape[0]

    @property
    def train_counts(self) -> jnp.ndarray:
        return jnp.full((self.num_clients,), self.per_client, jnp.int32)

    def _shard(self, key, rows: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ky, kn = jax.random.split(key)
        labels = jax.random.randint(ky, (rows,), 0, self.num_classes)
        imgs = (self.protos[labels]
                + self.noise * jax.random.normal(
                    kn, (rows,) + self.protos.shape[1:]))
        return imgs, labels

    def cohort_train(self, idx) -> Tuple[jnp.ndarray, jnp.ndarray]:
        base = jax.random.fold_in(self.key, TRAIN_STREAM)
        return jax.vmap(
            lambda i: self._shard(jax.random.fold_in(base, i),
                                  self.per_client))(idx)

    def tester_batches(self, tester_ids, eval_batch: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        base = jax.random.fold_in(self.key, TEST_STREAM)
        return jax.vmap(
            lambda i: self._shard(jax.random.fold_in(base, i),
                                  eval_batch))(tester_ids)

    def server_batch(self, eval_batch: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.server_x[:eval_batch], self.server_y[:eval_batch]


def make_synthetic_population(num_clients: int, *, per_client: int = 16,
                              image_size: int = 28, channels: int = 1,
                              num_classes: int = 10, noise: float = 0.45,
                              global_test: int = 256, server: int = 128,
                              seed: int = 0) -> SyntheticPopulation:
    """Build a :class:`SyntheticPopulation` of ``num_clients`` clients.

    Only the prototypes and the small global/server eval sets are
    materialised — construction cost is independent of ``num_clients``.
    """
    key = jax.random.PRNGKey(seed)
    k_proto, k_data = jax.random.split(key)
    protos = jax.random.normal(
        k_proto, (num_classes, image_size, image_size, channels))
    pop = SyntheticPopulation(
        key=k_data, protos=protos,
        global_x=jnp.zeros((0,)), global_y=jnp.zeros((0,)),
        server_x=jnp.zeros((0,)), server_y=jnp.zeros((0,)),
        num_clients=num_clients, per_client=per_client, noise=noise)
    gbase = jax.random.fold_in(k_data, GLOBAL_STREAM)
    gx, gy = pop._shard(jax.random.fold_in(gbase, 0), global_test)
    sx, sy = pop._shard(jax.random.fold_in(gbase, 1), server)
    return dataclasses.replace(pop, global_x=gx, global_y=gy,
                               server_x=sx, server_y=sy)
