"""One-call builders assembling FederatedDataset objects."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.data.partition import (
    build_client_arrays, dirichlet_partition, paper_noniid_partition)
from repro.data.pipeline import FederatedDataset, split_client_holdout
from repro.data.synthetic import ImageSpec, make_image_dataset


def make_federated_image_dataset(spec: ImageSpec, num_users: int,
                                 num_samples: int = 20_000,
                                 partition: str = "paper",
                                 partition_kwargs: Optional[dict] = None,
                                 holdout_frac: float = 0.2,
                                 server_frac: float = 0.1,
                                 global_test: int = 2_000,
                                 seed: int = 0) -> FederatedDataset:
    """``partition_kwargs`` are forwarded to the partitioner — e.g.
    ``{"min_classes": 8}`` for milder paper-style skew, or
    ``{"alpha": 0.1}`` for a sharper Dirichlet split."""
    x, y = make_image_dataset(spec, num_samples + global_test, seed=seed)
    gx, gy = x[num_samples:], y[num_samples:]
    x, y = x[:num_samples], y[:num_samples]

    # the server's held-out set for the accuracy-based baseline
    n_server = int(num_samples * server_frac)
    sx, sy = x[:n_server], y[:n_server]
    x, y = x[n_server:], y[n_server:]

    pkw = dict(partition_kwargs or {})
    if partition == "paper":
        parts = paper_noniid_partition(y, num_users, seed=seed + 1, **pkw)
    elif partition == "dirichlet":
        parts = dirichlet_partition(y, num_users, seed=seed + 1, **pkw)
    elif partition == "iid":
        idx = np.random.default_rng(seed + 1).permutation(len(y))
        parts = np.array_split(idx, num_users)
    else:
        raise ValueError(partition)

    xs, ys, counts = build_client_arrays(x, y, parts)
    train, test = split_client_holdout(xs, ys, counts, frac=holdout_frac)
    return FederatedDataset(
        train=train, test=test,
        global_x=jnp.asarray(gx), global_y=jnp.asarray(gy),
        server_x=jnp.asarray(sx), server_y=jnp.asarray(sy))
