from repro.data.synthetic import (
    make_image_dataset, make_token_stream, CIFAR_LIKE, MNIST_LIKE)
from repro.data.partition import (
    paper_noniid_partition, dirichlet_partition, build_client_arrays)
from repro.data.pipeline import (
    ClientData, FederatedDataset, sample_client_batches,
    split_client_holdout)
from repro.data.builders import make_federated_image_dataset
from repro.data.population import (
    DensePopulationData, SyntheticPopulation, make_synthetic_population)

__all__ = [
    "make_image_dataset", "make_token_stream", "CIFAR_LIKE", "MNIST_LIKE",
    "paper_noniid_partition", "dirichlet_partition", "build_client_arrays",
    "ClientData", "FederatedDataset", "sample_client_batches",
    "split_client_holdout", "make_federated_image_dataset",
    "DensePopulationData", "SyntheticPopulation",
    "make_synthetic_population",
]
