"""Synthetic datasets (offline stand-ins for CIFAR-10 / MNIST).

The container has no dataset downloads, so the paper's CIFAR-10 / MNIST
experiments run on *class-conditional synthetic images*: each class c has a
smooth random prototype; a sample is the prototype under a random shift +
per-sample Gaussian noise. Difficulty (noise scale, shift range, prototype
smoothing) is tuned so that (a) the paper's 3-conv CNN learns well above
chance within tens of steps, (b) harder "CIFAR-like" settings separate
strong/weak models while easier "MNIST-like" settings saturate — matching
the paper's observation that MNIST "does not sufficiently challenge
differentiating between strong and weak" models (Sec. IV).

LM-family FL experiments use a synthetic token stream with learnable
per-topic bigram structure.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageSpec:
    name: str
    image_size: int
    channels: int
    num_classes: int
    noise: float
    shift: int
    smooth: int


CIFAR_LIKE = ImageSpec("cifar_like", 32, 3, 10, noise=0.9, shift=4, smooth=4)
MNIST_LIKE = ImageSpec("mnist_like", 28, 1, 10, noise=0.45, shift=2, smooth=3)


def _smooth(x: np.ndarray, k: int) -> np.ndarray:
    """Cheap box-blur along spatial dims to create low-frequency prototypes."""
    for axis in (0, 1):
        acc = np.zeros_like(x)
        for d in range(-k, k + 1):
            acc += np.roll(x, d, axis=axis)
        x = acc / (2 * k + 1)
    return x


def make_image_dataset(spec: ImageSpec, num_samples: int, seed: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images [N,H,W,C] f32, labels [N] i32)."""
    rng = np.random.default_rng(seed)
    H = spec.image_size
    protos = rng.normal(size=(spec.num_classes, H, H, spec.channels))
    protos = np.stack([_smooth(p, spec.smooth) for p in protos])
    protos /= protos.std(axis=(1, 2, 3), keepdims=True) + 1e-8

    labels = rng.integers(0, spec.num_classes, size=num_samples)
    shifts = rng.integers(-spec.shift, spec.shift + 1, size=(num_samples, 2))
    images = protos[labels]
    for i in range(num_samples):
        images[i] = np.roll(images[i], tuple(shifts[i]), axis=(0, 1))
    images = images + rng.normal(scale=spec.noise,
                                 size=images.shape)
    return images.astype(np.float32), labels.astype(np.int32)


def make_token_stream(vocab: int, num_seqs: int, seq_len: int,
                      num_topics: int = 8, seed: int = 0,
                      noise: float = 0.15) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic LM data: per-topic affine bigram chains + noise tokens.

    Returns (tokens [N,S] i32, topics [N] i32). ``labels`` for next-token
    training are ``tokens`` shifted by the caller.
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(2, 17, size=num_topics)        # per-topic multiplier
    b = rng.integers(0, vocab, size=num_topics)     # per-topic offset
    topics = rng.integers(0, num_topics, size=num_seqs)
    toks = np.empty((num_seqs, seq_len), dtype=np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=num_seqs)
    for t in range(1, seq_len):
        nxt = (toks[:, t - 1] * a[topics] + b[topics]) % vocab
        noise_mask = rng.random(num_seqs) < noise
        nxt = np.where(noise_mask, rng.integers(0, vocab, size=num_seqs), nxt)
        toks[:, t] = nxt
    return toks.astype(np.int32), topics.astype(np.int32)
