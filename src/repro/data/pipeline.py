"""Federated data pipeline: client-stacked arrays + batch sampling.

The FL round engine vectorises local training across clients with ``vmap``,
so batches are materialised as [N_clients, local_steps, batch, ...] index
gathers from the stacked client arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClientData:
    """Stacked per-client dataset. Leaves: xs [N,M,...], ys [N,M,...]."""
    xs: jnp.ndarray
    ys: jnp.ndarray
    counts: jnp.ndarray            # [N] valid rows per client

    @property
    def num_clients(self) -> int:
        return self.xs.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FederatedDataset:
    train: ClientData
    # held-out *local* eval shards (the FedTest testers' data)
    test: ClientData
    # global eval set (convergence curves) + server set (accuracy-based)
    global_x: jnp.ndarray
    global_y: jnp.ndarray
    server_x: jnp.ndarray
    server_y: jnp.ndarray


def sample_client_batches(key, data: ClientData, steps: int, batch: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random-with-replacement batches per client.

    Returns (bx [N, steps, batch, ...], by [N, steps, batch, ...]).
    """
    N = data.num_clients
    u = jax.random.uniform(key, (N, steps, batch))
    idx = (u * data.counts[:, None, None]).astype(jnp.int32)
    bx = jax.vmap(lambda x, i: x[i])(data.xs, idx)
    by = jax.vmap(lambda y, i: y[i])(data.ys, idx)
    return bx, by


def split_client_holdout(xs: np.ndarray, ys: np.ndarray, counts: np.ndarray,
                         frac: float = 0.2):
    """Split stacked client arrays into train/test ClientData pairs."""
    N, M = xs.shape[0], xs.shape[1]
    n_test = np.maximum((counts * frac).astype(np.int32), 1)
    n_train = np.maximum(counts - n_test, 1)
    # test rows are the tail of each client's valid region
    test_x = np.zeros_like(xs)
    test_y = np.zeros_like(ys)
    for i in range(N):
        t = int(n_test[i])
        seg_x = xs[i, int(n_train[i]):int(counts[i])]
        seg_y = ys[i, int(n_train[i]):int(counts[i])]
        reps = int(np.ceil(M / max(len(seg_x), 1)))
        test_x[i] = np.tile(seg_x, (reps,) + (1,) * (xs.ndim - 2))[:M]
        test_y[i] = np.tile(seg_y, (reps,) + (1,) * (ys.ndim - 2))[:M]
    train = ClientData(jnp.asarray(xs), jnp.asarray(ys),
                       jnp.asarray(n_train.astype(np.int32)))
    test = ClientData(jnp.asarray(test_x), jnp.asarray(test_y),
                      jnp.asarray(n_test.astype(np.int32)))
    return train, test
