"""Non-IID client partitioners.

``paper_noniid_partition`` implements the paper's setup (Sec. III): "each
user randomly assigned a number of classes and a set of samples for each
class, ensuring a non-IID data distribution". ``dirichlet_partition`` is
the standard Dir(alpha) benchmark partitioner, included for ablations.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def paper_noniid_partition(labels: np.ndarray, num_users: int,
                           min_classes: int = 2, max_classes: int = 6,
                           seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    cursors = np.zeros(num_classes, dtype=int)

    user_classes = [list(rng.choice(num_classes,
                                    size=rng.integers(min_classes,
                                                      max_classes + 1),
                                    replace=False))
                    for _ in range(num_users)]
    # coverage guarantee: every class must have at least one holder, or the
    # federation could never learn it no matter the aggregator
    for c in range(num_classes):
        if not any(c in ucs for ucs in user_classes):
            user_classes[int(rng.integers(num_users))].append(c)
    # per-class fair share among the users holding that class
    holders = {c: [u for u in range(num_users) if c in user_classes[u]]
               for c in range(num_classes)}
    parts: List[List[int]] = [[] for _ in range(num_users)]
    for c, us in holders.items():
        if not us:
            continue
        pool = by_class[c]
        share = len(pool) // len(us)
        for u in us:
            lo = cursors[c]
            # randomise each user's sample count around the fair share
            take = max(int(share * rng.uniform(0.4, 1.0)), 1)
            take = min(take, len(pool) - lo)
            parts[u].extend(pool[lo:lo + take])
            cursors[c] += take
    return [np.array(sorted(p), dtype=np.int64) for p in parts]


def dirichlet_partition(labels: np.ndarray, num_users: int,
                        alpha: float = 0.5, seed: int = 0
                        ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    parts: List[List[int]] = [[] for _ in range(num_users)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_users)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for u, chunk in enumerate(np.split(idx, cuts)):
            parts[u].extend(chunk)
    return [np.array(sorted(p), dtype=np.int64) for p in parts]


def build_client_arrays(x: np.ndarray, y: np.ndarray,
                        parts: Sequence[np.ndarray]):
    """Pack per-client data into equal-capacity stacked arrays.

    Returns (xs [N, M, ...], ys [N, M], counts [N]) where M is the max
    client size; rows beyond ``counts[i]`` are repeats (never sampled when
    the pipeline respects counts).
    """
    N = len(parts)
    M = max(max(len(p) for p in parts), 1)
    xs = np.zeros((N, M) + x.shape[1:], dtype=x.dtype)
    ys = np.zeros((N, M) + y.shape[1:], dtype=y.dtype)
    counts = np.zeros((N,), dtype=np.int32)
    for i, p in enumerate(parts):
        n = len(p)
        counts[i] = n
        if n == 0:
            continue
        reps = int(np.ceil(M / n))
        sel = np.tile(p, reps)[:M]
        xs[i] = x[sel]
        ys[i] = y[sel]
    return xs, ys, counts
